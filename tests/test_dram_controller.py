"""System-level orderings the paper claims (Figs. 2-4), on synthetic traces."""
import jax
import pytest

from repro.core.dram.controller import (MechanismConfig, simulate_jit,
                                        weighted_speedup)
from repro.core.dram.traces import TraceConfig, generate

TCFG = TraceConfig(n_requests=8192)


@pytest.fixture(scope="module")
def results():
    tr = generate(jax.random.key(1), TCFG)
    cfgs = {
        "base": MechanismConfig(copy_mech="memcpy"),
        "rc": MechanismConfig(copy_mech="rc_intersa"),
        "lisa": MechanismConfig(copy_mech="lisa"),
        "villa": MechanismConfig(copy_mech="lisa", use_villa=True),
        "comb": MechanismConfig(copy_mech="lisa", use_villa=True,
                                use_lip=True),
        "lip": MechanismConfig(copy_mech="memcpy", use_lip=True),
        "rc_villa": MechanismConfig(copy_mech="memcpy", use_villa=True,
                                    villa_copy_mech="rc_intersa"),
    }
    out = {k: simulate_jit(tr, TCFG, c) for k, c in cfgs.items()}
    ws = {k: float(weighted_speedup(out["base"]["core_stall"],
                                    r["core_stall"]))
          for k, r in out.items()}
    return out, ws


def test_lisa_beats_rowclone_beats_memcpy(results):
    _, ws = results
    assert ws["lisa"] > ws["rc"] > ws["base"] == pytest.approx(1.0)


def test_villa_adds_over_risc_alone(results):
    _, ws = results
    assert ws["villa"] > ws["lisa"]          # paper: +16.5% over RISC


def test_lip_adds_over_risc_villa(results):
    _, ws = results
    assert ws["comb"] > ws["villa"]          # paper: +8.8% further


def test_lip_alone_modest_gain(results):
    _, ws = results
    assert 1.0 < ws["lip"] < 1.5             # paper: +10.3%


def test_rc_backed_villa_loses(results):
    _, ws = results
    assert ws["rc_villa"] < 1.0              # paper: -52.3%


def test_combined_energy_reduction(results):
    out, _ = results
    red = 1 - float(out["comb"]["energy_uJ"]) / float(out["base"]["energy_uJ"])
    assert red > 0.3                          # paper: -49% memory energy


def test_villa_hit_rate_meaningful(results):
    out, _ = results
    assert float(out["villa"]["villa_hit_rate"]) > 0.3


def test_workload_sweep_orderings_hold():
    """Mini version of the paper's 50-workload sweep: orderings must hold
    in the copy-heavy and locality-heavy corners too."""
    for copy_prob, zipf in [(0.002, 1.0), (0.02, 1.6)]:
        tcfg = TraceConfig(n_requests=4096, copy_prob=copy_prob, zipf_s=zipf)
        tr = generate(jax.random.key(7), tcfg)
        base = simulate_jit(tr, tcfg, MechanismConfig(copy_mech="memcpy"))
        lisa = simulate_jit(tr, tcfg, MechanismConfig(copy_mech="lisa"))
        comb = simulate_jit(tr, tcfg, MechanismConfig(
            copy_mech="lisa", use_villa=True, use_lip=True))
        ws_l = float(weighted_speedup(base["core_stall"], lisa["core_stall"]))
        ws_c = float(weighted_speedup(base["core_stall"], comb["core_stall"]))
        assert ws_l > 1.0
        assert ws_c > ws_l * 0.95    # combined never collapses below RISC
