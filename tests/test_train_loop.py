"""End-to-end training behaviour: loss decreases; crash/resume is exact."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, batch_at, host_shard
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import OptConfig, global_norm, init, schedule, update
from repro.train.step import ParallelConfig, init_train_state, make_train_step


def _setup(steps=40):
    cfg = get_reduced("tinyllama-1.1b")
    mesh = make_local_mesh(1, 1)
    pcfg = ParallelConfig(fsdp=False)
    ocfg = OptConfig(lr=8e-3, warmup_steps=2, total_steps=steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      repeat_len=8)
    state = init_train_state(cfg, jax.random.key(0), pcfg)
    _, compile_step, _ = make_train_step(cfg, mesh, pcfg, ocfg, donate=False)
    batch = batch_at(dcfg, 0)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          (state, batch))
    return state, compile_step(*shapes), dcfg


def test_loss_decreases():
    state, step_fn, dcfg = _setup()
    losses = []
    for s in range(40):
        state, m = step_fn(state, batch_at(dcfg, s))
        losses.append(float(m["ce"]))
    # 0.15 margin: the 40-step reduced-CPU run lands at ~0.24 decrease
    # (seed-dependent), so 0.25 flaked; 0.15 still fails any regression
    # that stalls or reverses training.
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.15, losses


def test_resume_is_bitexact(tmp_path):
    state, step_fn, dcfg = _setup()
    # run 6 steps, checkpoint at 3
    s = state
    for i in range(3):
        s, _ = step_fn(s, batch_at(dcfg, i))
    ckpt.save(s, str(tmp_path), 3)
    ref = s
    for i in range(3, 6):
        ref, _ = step_fn(ref, batch_at(dcfg, i))
    # crash + resume
    resumed = ckpt.restore(state, str(tmp_path))
    for i in range(3, 6):
        resumed, _ = step_fn(resumed, batch_at(dcfg, i))
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_determinism_and_sharding():
    dcfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1, b2 = batch_at(dcfg, 5), batch_at(dcfg, 5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(batch_at(dcfg, 6)["tokens"], b1["tokens"])
    # labels are next-token shifted
    assert np.array_equal(np.asarray(b1["labels"])[:, :-1],
                          np.asarray(b1["tokens"])[:, 1:])
    shards = [host_shard(b1, h, 4)["tokens"] for h in range(4)]
    assert np.array_equal(np.concatenate(shards), b1["tokens"])


def test_optimizer_units():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    grads = jax.tree.map(lambda p: jnp.full_like(p, 2.0), params)
    st = init(params)
    ocfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, clip_norm=1.0)
    newp, st2, m = update(ocfg, grads, st, params)
    assert float(m["grad_norm"]) > 1.0            # clipping engaged
    assert float(newp["w"].mean()) < 1.0          # moved against gradient
    assert int(st2.count) == 1
    # schedule: warmup then cosine decay to min ratio
    ocfg2 = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(ocfg2, jnp.int32(5))) < 1.0
    assert float(schedule(ocfg2, jnp.int32(100))) <= 0.1 + 1e-6
    assert float(global_norm({"a": jnp.ones(4)})) == 2.0


def test_grad_compression_training_still_learns():
    cfg = get_reduced("tinyllama-1.1b")
    mesh = make_local_mesh(1, 1)
    pcfg = ParallelConfig(fsdp=False, grad_compress=True)
    ocfg = OptConfig(lr=8e-3, warmup_steps=2, total_steps=32)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      repeat_len=8)
    state = init_train_state(cfg, jax.random.key(0), pcfg)
    _, compile_step, _ = make_train_step(cfg, mesh, pcfg, ocfg, donate=False)
    batch = batch_at(dcfg, 0)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          (state, batch))
    step_fn = compile_step(*shapes)
    losses = []
    for s in range(32):
        state, m = step_fn(state, batch_at(dcfg, s))
        losses.append(float(m["ce"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.15, losses
