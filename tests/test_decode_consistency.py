"""Decode-with-cache must equal full causal forward — every architecture.

This is the strongest model-correctness test in the suite: it exercises the
KV caches (full/ring-window), MLA compressed+absorbed decode, Mamba and
RWKV state single-step paths, MoE routing under tiny decode groups, and the
enc-dec prefill+decode path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_reduced
from repro.models import lm

DECODER_ARCHS = [a for a in ARCH_NAMES if a != "seamless-m4t-medium"]


def _rel_err(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = lm.init_lm(cfg, jax.random.key(3))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=32)
    step = jax.jit(lambda p, c, t, i: lm.decode_step(cfg, p, c, t, i))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits) < 1e-4


def test_encdec_prefill_then_decode():
    cfg = get_reduced("seamless-m4t-medium")
    params = lm.init_lm(cfg, jax.random.key(3))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    enc = jax.random.normal(jax.random.key(5), (B, 6, cfg.d_model)) * 0.02
    full_logits, _, _ = lm.forward(cfg, params, toks, enc_embeds=enc)
    cache = lm.init_cache(cfg, B, max_len=16, enc_len=6)
    lg, cache = lm.prefill(cfg, params, toks[:, :4], cache, enc_embeds=enc)
    outs = [lg[:, -1]]
    for t in range(4, S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits[:, 3:]) < 1e-4


def test_prefill_then_decode_gqa():
    """prefill() bulk cache write + subsequent decode == token-by-token."""
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(6))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=16)
    lg, cache = lm.prefill(cfg, params, toks[:, :6], cache)
    outs = [lg[:, -1]]
    for t in range(6, S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits[:, 5:]) < 1e-4


def test_batched_ragged_decode_parity_with_unbatched():
    """Drift guard for the serving hot path: at RAGGED slot positions,
    ``decode_step_batched`` must produce exactly the tokens and cache state
    that per-request ``decode_step`` produces on isolated single-slot
    caches.  (The engine's ``step_unbatched`` grouped path is A/B-only and
    NOT expected to match at ragged positions — this pins the batched path
    to the per-request truth instead.)"""
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(10))
    lens = (5, 9, 13)
    max_len = 32

    singles = []          # (next_token, single-slot cache, position)
    for i, ln in enumerate(lens):
        toks = jax.random.randint(jax.random.key(20 + i), (1, ln), 0,
                                  cfg.vocab_size)
        c1 = lm.init_cache(cfg, 1, max_len=max_len)
        lg, c1 = lm.prefill(cfg, params, toks, c1)
        singles.append((int(jnp.argmax(lg[0, -1])), c1, ln))

    # assemble the batched cache: slot i <- single cache i's slot 0
    cache = lm.init_cache(cfg, len(lens), max_len=max_len)
    for i, (_, c1, _) in enumerate(singles):
        cache = jax.tree.map(
            lambda full, one, i=i: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), i, axis=1), cache, c1)

    toks = jnp.asarray([t for t, _, _ in singles], jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)
    active = jnp.ones(len(lens), bool)
    for _ in range(3):                       # stays ragged every step
        nxt_b, cache = lm.decode_step_batched(cfg, params, cache, toks, pos,
                                              active)
        nxt_u, new_singles = [], []
        for (t, c1, p) in singles:
            lg, c1 = lm.decode_step(cfg, params, c1, jnp.asarray([[t]]),
                                    jnp.int32(p))
            nxt_u.append(int(jnp.argmax(lg[0, 0])))
            new_singles.append((nxt_u[-1], c1, p + 1))
        singles = new_singles
        assert [int(t) for t in nxt_b] == nxt_u      # identical tokens
        for i, (_, c1, _) in enumerate(singles):     # identical cache state
            for bl, ul in zip(jax.tree.leaves(cache), jax.tree.leaves(c1)):
                assert bl.dtype == ul.dtype
                assert jnp.allclose(bl[:, i].astype(jnp.float32),
                                    ul[:, 0].astype(jnp.float32),
                                    atol=1e-5, rtol=1e-5)
        toks, pos = nxt_b, pos + 1


def test_sliding_window_ring_cache_long_decode():
    """gemma3-style window cache: decode far past the window size stays
    consistent with the full forward (ring buffer overwrites oldest)."""
    cfg = dataclasses.replace(get_reduced("gemma3-27b"), window=8)
    params = lm.init_lm(cfg, jax.random.key(8))
    B, S = 1, 24                      # 3x window
    toks = jax.random.randint(jax.random.key(9), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits) < 1e-4
