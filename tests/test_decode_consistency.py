"""Decode-with-cache must equal full causal forward — every architecture.

This is the strongest model-correctness test in the suite: it exercises the
KV caches (full/ring-window), MLA compressed+absorbed decode, Mamba and
RWKV state single-step paths, MoE routing under tiny decode groups, and the
enc-dec prefill+decode path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_reduced
from repro.models import lm

DECODER_ARCHS = [a for a in ARCH_NAMES if a != "seamless-m4t-medium"]


def _rel_err(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = lm.init_lm(cfg, jax.random.key(3))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=32)
    step = jax.jit(lambda p, c, t, i: lm.decode_step(cfg, p, c, t, i))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits) < 1e-4


def test_encdec_prefill_then_decode():
    cfg = get_reduced("seamless-m4t-medium")
    params = lm.init_lm(cfg, jax.random.key(3))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    enc = jax.random.normal(jax.random.key(5), (B, 6, cfg.d_model)) * 0.02
    full_logits, _, _ = lm.forward(cfg, params, toks, enc_embeds=enc)
    cache = lm.init_cache(cfg, B, max_len=16, enc_len=6)
    lg, cache = lm.prefill(cfg, params, toks[:, :4], cache, enc_embeds=enc)
    outs = [lg[:, -1]]
    for t in range(4, S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits[:, 3:]) < 1e-4


def test_prefill_then_decode_gqa():
    """prefill() bulk cache write + subsequent decode == token-by-token."""
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(6))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=16)
    lg, cache = lm.prefill(cfg, params, toks[:, :6], cache)
    outs = [lg[:, -1]]
    for t in range(6, S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits[:, 5:]) < 1e-4


def test_sliding_window_ring_cache_long_decode():
    """gemma3-style window cache: decode far past the window size stays
    consistent with the full forward (ring buffer overwrites oldest)."""
    cfg = dataclasses.replace(get_reduced("gemma3-27b"), window=8)
    params = lm.init_lm(cfg, jax.random.key(8))
    B, S = 1, 24                      # 3x window
    toks = jax.random.randint(jax.random.key(9), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert _rel_err(dec, full_logits) < 1e-4
