"""Per-architecture smoke: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (task spec, deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.optim.adamw import OptConfig
from repro.train.step import ParallelConfig, init_train_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = lm.init_lm(cfg, jax.random.key(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.encdec:
        kw["enc_embeds"] = jax.random.normal(
            jax.random.key(2), (B, 16, cfg.d_model)) * 0.02
    logits, aux, _ = jax.jit(lambda p, t: lm.forward(cfg, p, t, **kw))(
        params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_runs_and_is_finite(arch):
    cfg = get_reduced(arch)
    mesh = make_local_mesh(1, 1)
    pcfg = ParallelConfig(fsdp=False)
    state = init_train_state(cfg, jax.random.key(0), pcfg)
    _, compile_step, _ = make_train_step(
        cfg, mesh, pcfg, OptConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    batch = batch_at(dcfg, 0)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None, None],
                               (3, 2, 32))
        batch = dict(batch, positions=pos)
    if cfg.encdec:
        batch = dict(batch, enc_embeds=jax.random.normal(
            jax.random.key(3), (2, 16, cfg.d_model)) * 0.02)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          (state, batch))
    step_fn = compile_step(*shapes)
    # snapshot before the step: the step donates its input state
    import numpy as np
    leaf0 = np.asarray(jax.tree_util.tree_leaves(state.params)[1])
    state2, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually changed
    leaf1 = np.asarray(jax.tree_util.tree_leaves(state2.params)[1])
    assert not np.allclose(leaf0, leaf1)
