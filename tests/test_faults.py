"""Chaos subsystem: seeded fault injection, checksummed movement with
priced retries, and snapshot-backed replica-failure recovery.

``CHAOS_SEED`` (the CI matrix knob) offsets every fault seed used here, so
the determinism and zero-silent-corruption claims are exercised per RNG
stream, never against one blessed seed.  Property tests ride
``_hypothesis_compat`` (skip cleanly without hypothesis); each has a
fixed-case fallback that always runs.
"""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import movement as MV
from repro import sched
from repro.checkpoint.manager import CorruptCheckpoint
from repro.configs import get_reduced
from repro.faults import (FAULT_CODES, NULL_FAULT, FaultInjector, FaultSpec,
                          apply_fault, fault_kinds, load_snapshots,
                          restore_session, save_snapshots, snapshot_sessions)
from repro.models import lm
from repro.movement import paging as PG
from repro.serve.cluster import Cluster
from repro.serve.engine import Request

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    return cfg, lm.init_lm(cfg, jax.random.key(0))


def _greedy_reference(cfg, params, prompt, n_new, max_len=48):
    cache = lm.init_cache(cfg, 1, max_len=max_len)
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < n_new:
        lg, cache = lm.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]]), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


def _drain(cl, uid, prompt, max_new, replica):
    req = Request(uid=uid, prompt=prompt, max_new=max_new)
    cl.submit(req, replica=replica)
    while cl.active:
        cl.step()
    return req


# ---------------------------------------------------------------------------
# checksum sidecar: every single-byte corruption is detected
# ---------------------------------------------------------------------------

_DTYPES = {"int8": np.int8, "bf16": "bf16", "f32": np.float32}


def _typed_pages(seed, dtname, n_pages=3, P=4, d=8):
    """A (n_pages, P, d*itemsize) uint8 page block whose bytes are a REAL
    typed payload (int8 / bf16 / f32 values), not arbitrary noise — the
    sidecar must detect flips in the byte patterns serving actually moves."""
    rng = np.random.default_rng((CHAOS_SEED, seed))
    if dtname == "int8":
        arr = rng.integers(-128, 128, (n_pages, P, d)).astype(np.int8)
    elif dtname == "f32":
        arr = rng.standard_normal((n_pages, P, d)).astype(np.float32)
    else:                                   # bf16 via jnp (numpy lacks it)
        arr = np.asarray(jnp.asarray(
            rng.standard_normal((n_pages, P, d)), jnp.bfloat16
        ).view(jnp.uint8))
    raw = np.frombuffer(arr.tobytes(), np.uint8)
    return raw.reshape(n_pages, P, -1).copy()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(sorted(_DTYPES)),
       st.integers(0, 10**9), st.integers(1, 255))
def test_checksum_detects_every_single_byte_flip(seed, dtname, pos, xor):
    """Property: for ANY payload, position and nonzero xor, flipping one
    byte flips exactly that page's checksum (the odd position weights make
    every single-byte delta visible mod 2^32)."""
    pages = _typed_pages(seed, dtname)
    sums = PG.page_checksums(jnp.asarray(pages))
    assert int(PG.verify_pages(jnp.asarray(pages), sums)) == 0
    flat = pages.reshape(-1)
    flat[pos % flat.size] ^= xor
    corrupt = flat.reshape(pages.shape)
    assert int(PG.verify_pages(jnp.asarray(corrupt), sums)) == 1


def test_checksum_detects_single_byte_flip_fixed_cases():
    """Fixed-case fallback: first/last byte of each dtype's block, plus the
    all-zero payload (a zeroed byte in a zero page is the adversarial case
    for sum-style checksums; position weighting still catches xor flips)."""
    for dtname in sorted(_DTYPES):
        pages = _typed_pages(1, dtname)
        sums = PG.page_checksums(jnp.asarray(pages))
        for pos in (0, pages.size - 1, pages.size // 2):
            flat = pages.copy().reshape(-1)
            flat[pos] ^= 0xA5
            bad = flat.reshape(pages.shape)
            assert int(PG.verify_pages(jnp.asarray(bad), sums)) == 1
    zero = np.zeros((2, 4, 16), np.uint8)
    zsums = PG.page_checksums(jnp.asarray(zero))
    zero[1, 2, 3] = 7
    assert int(PG.verify_pages(jnp.asarray(zero), zsums)) == 1


def test_fault_mode_registry_and_apply():
    """The fifth registry: flip_byte / drop_page are registered with
    deterministic codes; apply_fault is gated (NULL_FAULT is identity) and
    drop_page zeroes exactly the indexed page."""
    assert set(fault_kinds()) == {"flip_byte", "drop_page"}
    assert FAULT_CODES["none"] == 0
    pages = jnp.asarray(_typed_pages(2, "f32"))
    same = apply_fault(pages, jnp.asarray(NULL_FAULT))
    assert bool(jnp.array_equal(same, pages))
    drop = apply_fault(pages, jnp.asarray(
        [FAULT_CODES["drop_page"], 1, 0], jnp.int32))
    assert not bool(jnp.any(drop[1]))
    assert bool(jnp.array_equal(drop[0], pages[0]))
    flip = apply_fault(pages, jnp.asarray(
        [FAULT_CODES["flip_byte"], 5, 0x40], jnp.int32))
    diff = np.asarray(flip).reshape(-1) != np.asarray(pages).reshape(-1)
    assert diff.sum() == 1 and diff[5]


# ---------------------------------------------------------------------------
# retry pricing: k retries == k x the leg plan, NO backoff in the movement
# bill — backoff is mechanism-independent waiting, charged to the clock in
# its own Decision bucket so the lisa/memcpy ratio is fault-rate-invariant
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 6))
def test_retry_cost_is_additive(k):
    base = MV.MovementCost(4096, 3, 120.0, 950.0, 0.7, 5.3)
    rc = MV.retry_cost(base, k)
    assert rc.ns_lisa == pytest.approx(base.ns_lisa * k)
    assert rc.ns_memcpy == pytest.approx(base.ns_memcpy * k)
    assert rc.uj_lisa == pytest.approx(base.uj_lisa * k)
    assert rc.bytes == base.bytes * k
    if k:
        # the headline ratio survives any retry count: retries scale both
        # mechanisms by the same k, so the per-decision advantage is the
        # base plan's advantage exactly
        assert (rc.ns_memcpy / rc.ns_lisa
                == pytest.approx(base.ns_memcpy / base.ns_lisa))


def test_retry_cost_fixed_cases():
    base = MV.MovementCost(1000, 1, 10.0, 50.0, 1.0, 5.0)
    zero = MV.retry_cost(base, 0)
    assert zero.bytes == 0 and zero.ns_lisa == 0.0
    three = MV.retry_cost(base, 3)
    assert three.bytes == 3000 and three.ns_lisa == pytest.approx(30.0)
    assert three.ns_memcpy == pytest.approx(150.0)
    # retries never touch the energy books beyond the k-fold re-copy
    assert three.uj_lisa == pytest.approx(3.0)


def test_injector_is_replayable_and_counter_based():
    """Two injectors with the same spec emit identical draw sequences
    (counter-based RNG, no global state); a different seed diverges."""
    spec = FaultSpec(rate=0.5, seed=CHAOS_SEED + 13)
    a, b = FaultInjector(spec), FaultInjector(spec)
    seq_a = [a.draw_movement(4096, 8).tolist() for _ in range(20)]
    seq_b = [b.draw_movement(4096, 8).tolist() for _ in range(20)]
    assert seq_a == seq_b
    c = FaultInjector(FaultSpec(rate=0.5, seed=CHAOS_SEED + 14))
    assert [c.draw_movement(4096, 8).tolist() for _ in range(20)] != seq_a
    # the ledger closes every incident into exactly one bucket
    inj = FaultInjector(spec)
    assert inj.note_corrupt(7) and not inj.note_corrupt(7)   # merge
    inj.note_corrupt(8)
    inj.note_corrupt(9)
    inj.consume_corrupt(7, "detected")
    inj.consume_corrupt(8, "recovered")
    inj.discard_corrupt(9)
    s = inj.summary()
    assert (s["new_corrupt"], s["merged"]) == (3, 1)
    assert (s["detected"], s["recovered"], s["destroyed"]) == (1, 1, 1)
    assert s["at_rest_corrupt"] == 0
    assert inj.backoff_ns(1) == 500.0 and inj.backoff_ns(2) == 1000.0
    assert inj.backoff_ns(50) == 8000.0                       # capped


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="rate"):
        FaultSpec(rate=1.5)
    with pytest.raises(ValueError, match="max_retries"):
        FaultSpec(max_retries=-1)
    with pytest.raises(ValueError, match="kinds"):
        FaultSpec(kinds=())
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjector(FaultSpec(kinds=("bitrot_gamma",)))


# ---------------------------------------------------------------------------
# checksummed movement: corrupted migration legs retry until clean
# ---------------------------------------------------------------------------

def test_migration_retries_until_clean_and_stays_bit_exact(setup):
    """Under a heavy movement-fault rate with recovery armed, migrations
    re-issue corrupted hop chains from the intact source: every wave whose
    event closes clean lands bit-exactly, retries are counted, and the
    retry events price as k x the route plan (cost-additivity e2e)."""
    cfg, params = setup
    inj = FaultInjector(FaultSpec(rate=0.6, seed=CHAOS_SEED + 3,
                                  max_retries=8))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8, faults=inj)
    rng = np.random.default_rng(CHAOS_SEED)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    _drain(cl, 3, prompt, 3, replica=0)
    src = 0
    n_retries, n_events = 0, 0
    for _ in range(10):
        dst = 1 - src
        want = np.asarray(cl.replicas[src].sessions.slow[3]).copy()
        cl.migrate(3, dst)
        for ev in cl.drain_fault_events():
            n_events += 1
            n_retries += ev["retries"]
            assert ev["retries"] <= inj.spec.max_retries
            if ev["corrupt_uid"] is None:
                got = np.asarray(cl.replicas[dst].sessions.slow[3])
                assert np.array_equal(got, want)    # clean == bit-exact
            else:
                assert inj.is_corrupt(ev["corrupt_uid"])
                inj.consume_corrupt(ev["corrupt_uid"], "detected")
        src = dst
    s = inj.summary()
    assert s["movement_fired"] >= 1                 # rate 0.6 over 10 waves
    assert s["retries"] == n_retries
    # every incident (one drained event each) closes into exactly one
    # bucket: retried clean, landed corrupt (new), or merged into an
    # already-open corruption.  ``fired`` also counts the re-fires of
    # retry attempts, so it bounds the incidents from above.
    assert n_events == s["retry_fixed"] + s["new_corrupt"] + s["merged"]
    assert s["fired"] >= n_events
    # retry pricing is k x the already-priced route plan — backoff is NOT
    # movement and lives in the Decision's own backoff_ns bucket
    base = cl.migration_plan(0, 1).cost
    rc = MV.retry_cost(base, 2)
    assert rc.ns_lisa == pytest.approx(2 * base.ns_lisa)
    assert rc.ns_memcpy == pytest.approx(2 * base.ns_memcpy)


def test_corrupt_at_rest_is_detected_on_resume(setup):
    """An at-rest byte flip under a session's feet is caught by the
    device-side verify at the next resume — the counter is folded into the
    jitted resume (no extra host sync) and read back once, explicitly."""
    cfg, params = setup
    inj = FaultInjector(FaultSpec(rate=0.0, seed=CHAOS_SEED))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8, faults=inj)
    rng = np.random.default_rng(CHAOS_SEED + 1)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    _drain(cl, 2, prompt, 3, replica=0)
    assert cl.verify_failure_count() == 0
    eng = cl.replicas[0]
    eng.corrupt_stored(2 % eng.n_sessions, page=0, byte=5, xor=0x11)
    assert int(cl.scrub()) == 1                     # at rest: scrub sees it
    cl.resume(2, extra_new=2)
    while cl.active:
        cl.step()
    assert cl.verify_failure_count() == 1           # resume verify caught it


# ---------------------------------------------------------------------------
# snapshot-backed recovery: replica death, bit-exact resumption
# ---------------------------------------------------------------------------

def test_failed_replica_restore_decodes_bit_exact(setup):
    """The PR 5 parity chain extended across a failure: drain on replica 0,
    snapshot, kill replica 0, restore from the snapshot on replica 1 —
    the remaining decode matches the uninterrupted run token-for-token and
    passes the checksum verify (the snapshot carries the sidecar row)."""
    cfg, params = setup
    rng = np.random.default_rng(CHAOS_SEED + 2)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    straight = _greedy_reference(cfg, params, prompt, 8)
    inj = FaultInjector(FaultSpec(rate=0.0, seed=CHAOS_SEED))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8, faults=inj)
    req = _drain(cl, 7, prompt, 4, replica=0)
    snaps, cost = snapshot_sessions(cl)
    assert 7 in snaps and cost.bytes > 0            # priced, not free
    inflight, suspended = cl.fail_replica(0)
    assert inflight == [] and 7 in suspended
    assert 7 not in cl.session_pos                  # state died with it
    restore_session(cl, snaps[7], 1)
    assert cl.residence[7] == 1
    slot = cl.resume(7, extra_new=5)
    r2 = cl.active[slot]
    while cl.active:
        cl.step()
    assert req.generated + r2.generated[1:] == straight
    assert cl.verify_failure_count() == 0           # restored bytes verify


def test_snapshots_persist_and_reject_torn_files(tmp_path, setup):
    """Snapshot sets round-trip through the checkpoint manager's atomic
    format; a truncated arrays file is rejected as CorruptCheckpoint, never
    restored as garbage sessions."""
    cfg, params = setup
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8)
    rng = np.random.default_rng(CHAOS_SEED + 4)
    for uid in (1, 5):
        _drain(cl, uid, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
               3, replica=0)
    snaps, _ = snapshot_sessions(cl)
    save_snapshots(snaps, str(tmp_path), step=3)
    back = load_snapshots(str(tmp_path))
    assert sorted(back) == [1, 5]
    for uid in (1, 5):
        assert back[uid].pos == snaps[uid].pos
        assert np.array_equal(back[uid].pages, snaps[uid].pages)
        assert np.array_equal(back[uid].sums, snaps[uid].sums)
    npz = tmp_path / "step_00000003" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:-40])         # torn write
    with pytest.raises(CorruptCheckpoint):
        load_snapshots(str(tmp_path))


def test_scheduler_survives_replica_failure(setup):
    """A scheduled mid-run replica death: recoverable sessions re-admit
    from snapshots via the priced channel, the rest re-queue under their
    original admission seq, and the run completes every offered job."""
    cfg, params = setup
    wl = sched.WorkloadConfig(n_fresh=4, n_followups=6)
    arrivals = sched.generate_workload(wl, seed=5, vocab_size=cfg.vocab_size)
    inj = FaultInjector(FaultSpec(rate=0.0, seed=CHAOS_SEED,
                                  replica_failures=((25, 1),)))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=sched.n_sessions_for(wl), faults=inj)
    s = sched.ClusterScheduler(cl, arrivals=arrivals, snapshot_every=8)
    summary = s.run()
    assert summary["jobs_completed"] == len(arrivals)
    f = summary["faults"]["counters"]
    assert f["replica_failures"] == 1
    assert f.get("recovered", 0) + f.get("requeued", 0) \
        + f.get("lost", 0) >= 1                     # the failure had teeth
    # nothing lands on the dead replica afterwards
    assert all(r == 0 for r in cl.residence.values())
    # snapshot waves are priced but never charged to the critical path
    kinds = s.metrics.decision_counts()
    assert kinds.get("snapshot_wave", 0) >= 1


def test_chaos_run_is_deterministic_per_seed(setup):
    """The whole chaos pipeline replays bit-identically from (spec, seed):
    same ledger, same device detections, same job metrics — and a
    different chaos seed leaves the clean-run job count intact (faults
    cost latency, never correctness)."""
    cfg, params = setup
    wl = sched.WorkloadConfig(n_fresh=4, n_followups=6)
    arrivals = sched.generate_workload(wl, seed=5, vocab_size=cfg.vocab_size)

    def run(seed):
        inj = FaultInjector(FaultSpec(rate=0.4, seed=seed))
        cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                     n_sessions=sched.n_sessions_for(wl), faults=inj)
        s = sched.ClusterScheduler(cl, arrivals=arrivals, snapshot_every=2)
        summary = s.run()
        return (inj.summary(), cl.verify_failure_count(), int(cl.scrub()),
                summary["jobs_completed"], summary["p99_latency_ns"])

    a = run(CHAOS_SEED + 21)
    b = run(CHAOS_SEED + 21)
    assert a == b
    led, vf, scrub, jobs, _ = a
    assert jobs == len(arrivals)
    # zero-silent-corruption: device detections + at-rest scrub close every
    # incident the ledger opened
    assert vf == led["detected"]
    assert scrub == led["at_rest_corrupt"]
    assert led["new_corrupt"] == (led["detected"] + led["recovered"]
                                  + led["destroyed"]
                                  + led["at_rest_corrupt"])


def test_degraded_fast_tier_reroutes_pricing(setup):
    """degrade_fast turns the VILLA fast tier off: the engine reports no
    fast residents, resume pricing falls back to slow-tier costs, and the
    cluster policy sorts the degraded replica behind healthy ones."""
    cfg, params = setup
    inj = FaultInjector(FaultSpec(rate=0.0, seed=CHAOS_SEED,
                                  degrade_fast=((0, 1),)))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8, faults=inj)
    cl.degrade_fast(1)
    assert cl.replicas[1].fast_degraded
    assert not cl.replicas[1].fast_resident_uids()
    # policy: equal slots + equal price -> healthy replica wins
    from repro.sched.policy import PlaceCand, SchedContext, get_policy
    pol = get_policy("cost_aware_cluster")
    cands = [PlaceCand(replica=1, free_slots=2, fast_occupancy=0.0,
                       hop_ns=0.0, place_ns=100.0, degraded=True),
             PlaceCand(replica=0, free_slots=2, fast_occupancy=0.0,
                       hop_ns=0.0, place_ns=100.0, degraded=False)]
    order = pol.place_order(cands, SchedContext(tick=0, now_ns=0.0,
                                                mechanism="lisa"))
    assert [c.replica for c in order] == [0, 1]
