"""The movement substrate: plan lowering, cost pricing, backend fidelity.

Covers the satellite contract: hop counts linear in mesh distance matching
the ``DramSpec`` mechanism pricing, bit-exact round trips for every
registered backend on int8 / bf16 / f32 leaves, and the fused-wave and
registry invariants the serving engine relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _multidev import run_with_devices

from repro import movement as MV
from repro.core.dram.spec import DDR3_1600
from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.core.lisa.topology import (MeshTopology, hop_chain_us,
                                      ici_dram_spec, ring_collective_us)

DTYPES = [jnp.int8, jnp.bfloat16, jnp.float32]
LAYOUT = MV.Layout.dense((64, 128), jnp.float32)


def _rand(key, shape, dtype):
    if np.dtype(dtype).kind in "iu":
        return jax.random.randint(key, shape, -100, 100).astype(dtype)
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# Plan lowering + cost model.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(2, 16), st.integers(0, 63), st.integers(0, 63))
def test_hop_chain_legs_linear_in_mesh_distance(n, a, b):
    """Point-to-point stage transfers lower to ONE hop-chain leg whose hop
    count is the topology distance, priced exactly by the ICI DramSpec's
    ``lisa`` mechanism (Table 1's linear model re-parameterised)."""
    src, dst = a % n, b % n
    topo = MeshTopology(n)
    p = MV.plan(MV.Transfer(MV.Tier("stage", index=src, axis="x"),
                            MV.Tier("stage", index=dst, axis="x"), LAYOUT),
                topo=topo)
    (leg,) = p.legs
    h = topo.hops(src, dst)
    assert leg.hops == h
    assert p.cost.ns_lisa == pytest.approx(
        ici_dram_spec(LAYOUT.nbytes).copy_latency("lisa", h) if h else 0.0)
    assert p.cost.ns_lisa == pytest.approx(hop_chain_us(h, LAYOUT.nbytes)
                                           * 1e3)


def test_hop_cost_increments_are_constant_per_hop():
    topo = MeshTopology(32, wraparound=False)
    ns = []
    for d in range(1, 8):
        p = MV.plan(MV.Transfer(MV.Tier("stage", index=0, axis="x"),
                                MV.Tier("stage", index=d, axis="x"), LAYOUT),
                    topo=topo)
        ns.append(p.cost.ns_lisa)
    diffs = {round(b - a, 6) for a, b in zip(ns, ns[1:])}
    assert len(diffs) == 1                       # strictly linear in hops
    per_hop = diffs.pop()
    assert per_hop == pytest.approx(
        ici_dram_spec(LAYOUT.nbytes).lisa.t_rbm_hop)


def test_ring_plan_matches_collective_pricing():
    """ring_scan-style collectives: (n-1) shift legs for gather/scatter,
    2(n-1) for all-reduce, priced identically to topology's model."""
    for kind, steps in [("all_gather", 7), ("reduce_scatter", 7),
                        ("all_reduce", 14)]:
        p = MV.ring_plan("x", 8, LAYOUT, kind)
        assert len(p.legs) == steps
        assert all(l.kind == "hop_chain" and l.hops == 1 for l in p.legs)
        assert p.cost.ns_lisa == pytest.approx(
            ring_collective_us(8, LAYOUT.nbytes, kind) * 1e3)


def test_paged_tier_plan_prices_like_table1_rows():
    """In-device paged legs price rows x copy_latency — the engine's
    modeled suspend/resume accounting (Table 1 at serving granularity)."""
    cache = {"k": jnp.zeros((2, 3, 7, 9), jnp.bfloat16)}
    spec = MV.PageSpec.for_cache(cache)
    cfg = VillaConfig(n_counters=4, n_hot=1, n_slots=1, epoch_len=4)
    p = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                            MV.Layout.pages(spec), policy=cfg), DDR3_1600)
    rows = max(1, -(-spec.total_bytes // DDR3_1600.row_bytes))
    assert [l.kind for l in p.legs] == ["pack_pages", "tier_write"]
    assert p.cost.bytes == spec.total_bytes
    assert p.cost.ns_lisa == pytest.approx(
        rows * DDR3_1600.copy_latency("lisa", 1))
    assert p.cost.ns_memcpy == pytest.approx(
        rows * DDR3_1600.copy_latency("memcpy"))
    assert p.cost.advantage > 1.0                # the Table 1 gap survives


def test_fuse_scales_cost_and_batches_legs():
    cache = {"k": jnp.zeros((2, 3, 7, 9), jnp.float32)}
    spec = MV.PageSpec.for_cache(cache)
    cfg = VillaConfig(n_counters=4, n_hot=1, n_slots=1, epoch_len=4)
    single = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("compute"),
                                 MV.Layout.pages(spec), policy=cfg))
    wave = MV.fuse([single] * 3)
    assert wave.transfer.layout.batch == 3
    assert all(l.batch == 3 for l in wave.legs)
    assert wave.cost.ns_lisa == pytest.approx(3 * single.cost.ns_lisa)
    assert wave.cost.bytes == 3 * single.cost.bytes
    with pytest.raises(ValueError, match="identical"):
        MV.fuse([single, MV.plan(MV.Transfer(
            MV.Tier("compute"), MV.Tier("slow"), MV.Layout.pages(spec),
            policy=cfg))])


def test_backend_registry_is_reload_safe():
    """Reloading a registering module re-registers the same backends
    without error (same module/qualname replaces); a DIFFERENT function
    under a taken kind still raises."""
    import importlib
    import repro.core.lisa.villa_cache as VCm
    import repro.movement.backends as B
    importlib.reload(B)
    importlib.reload(VCm)
    assert {"tier_read", "tier_write", "page_gather"} <= set(
        MV.backend_kinds())
    with pytest.raises(ValueError, match="already registered"):
        MV.register_backend("tier_read")(lambda leg, env: env)


def test_fuse_rejects_non_wave_legs_and_suspend_waves_fuse():
    """fuse() refuses legs whose backends run one item per dispatch (a
    fused raw gather would move one item while charging k); policy-staged
    suspend plans DO fuse — a k-slot suspend wave equals k sequential
    suspends."""
    raw = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("compute"),
                              MV.Layout.raw_pages(4, 8, 128, jnp.uint8)))
    with pytest.raises(ValueError, match="cannot batch"):
        MV.fuse([raw] * 2)

    cache = {"a": _rand(jax.random.key(9), (2, 3, 5, 7), jnp.float32)}
    spec = MV.PageSpec.for_cache(cache)
    cfg = VillaConfig(n_counters=4, n_hot=2, n_slots=2, epoch_len=4)
    susp = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                               MV.Layout.pages(spec), policy=cfg))
    slots = jnp.asarray([0, 2], jnp.int32)
    items = jnp.asarray([3, 1], jnp.int32)

    st_w = VC.make_store(jnp.zeros((4, spec.n_pages, 8, 128), jnp.uint8),
                         cfg)
    st_w = MV.execute(MV.fuse([susp] * 2), cache=cache, slots=slots,
                      store=st_w, items=items)["store"]
    st_s = VC.make_store(jnp.zeros((4, spec.n_pages, 8, 128), jnp.uint8),
                         cfg)
    for s, i in zip(slots, items):
        st_s = MV.execute(susp, cache=cache, slot=s, store=st_s,
                          item=i)["store"]
    assert (np.asarray(st_w.slow) == np.asarray(st_s.slow)).all()


def test_unknown_lowering_and_backend_raise_clearly():
    with pytest.raises(ValueError, match="no lowering"):
        MV.plan(MV.Transfer(MV.Tier("host"), MV.Tier("slow"), LAYOUT))
    with pytest.raises(ValueError, match="unknown movement backend"):
        MV.get_backend("warp_drive")
    # point-to-point stage plans must not guess the ring size: the priced
    # hop count would diverge from the route lisa_copy executes
    with pytest.raises(ValueError, match="mesh topology"):
        MV.plan(MV.Transfer(MV.Tier("stage", index=3, axis="x"),
                            MV.Tier("stage", index=0, axis="x"), LAYOUT))
    # the policy decides fast-tier placement; policy transfers name slow
    cfg = VillaConfig(n_counters=4, n_hot=1, n_slots=1, epoch_len=4)
    with pytest.raises(ValueError, match="slow tier"):
        MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("fast"), LAYOUT,
                            policy=cfg))
    # every leg kind a plan can emit has a registered backend
    for kind in ("pack_pages", "unpack_pages", "page_gather", "page_scatter",
                 "tier_read", "tier_write", "tile_copy", "hop_chain",
                 "host_stage"):
        assert kind in MV.backend_kinds()


# ---------------------------------------------------------------------------
# Backend fidelity: bit-exact round trips on int8 / bf16 / f32.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_tile_copy_backend_bit_exact(dtype):
    x = _rand(jax.random.key(0), (37, 129), dtype)
    p = MV.plan(MV.Transfer(MV.Tier("device"), MV.Tier("device"),
                            MV.Layout.dense(x.shape, dtype)))
    out = MV.execute(p, data=x)["data"]
    assert out.dtype == x.dtype
    assert (np.asarray(out) == np.asarray(x)).all()


@pytest.mark.parametrize("dtype", DTYPES)
def test_page_scatter_gather_backends_bit_exact(dtype):
    pool = _rand(jax.random.key(1), (16, 8, 128), dtype)
    upd = _rand(jax.random.key(2), (4, 8, 128), dtype)
    table = jnp.asarray([3, 0, 11, 7], jnp.int32)
    lay = MV.Layout.raw_pages(4, 8, 128, dtype)
    wr = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"), lay))
    rd = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("compute"), lay))
    pool2 = MV.execute(wr, pool=pool, table=table, data=upd)["pool"]
    back = MV.execute(rd, pool=pool2, table=table)["data"]
    assert back.dtype == dtype
    assert (np.asarray(back) == np.asarray(upd)).all()


@pytest.mark.parametrize("dtype", DTYPES)
def test_tier_promotion_plan_moves_pages_across_pools(dtype):
    """slow->fast promotion: the gather leg reads the SOURCE pool and the
    scatter leg writes the DESTINATION pool (distinct env keys) — the pages
    land in the fast pool bit-exactly and the slow pool is untouched."""
    slow = _rand(jax.random.key(8), (32, 8, 128), dtype)
    fast = jnp.zeros((8, 8, 128), dtype)
    p = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("fast"),
                            MV.Layout.raw_pages(2, 8, 128, dtype)))
    assert [l.kind for l in p.legs] == ["page_gather", "page_scatter"]
    env = MV.execute(p, src_pool=slow,
                     src_table=jnp.asarray([4, 21], jnp.int32),
                     dst_pool=fast, dst_table=jnp.asarray([3, 0], jnp.int32))
    out = env["dst_pool"]
    assert (np.asarray(out[3]) == np.asarray(slow[4])).all()
    assert (np.asarray(out[0]) == np.asarray(slow[21])).all()
    untouched = [i for i in range(8) if i not in (0, 3)]
    assert (np.asarray(out[jnp.asarray(untouched)]) == 0).all()
    assert (np.asarray(env["src_pool"]) == np.asarray(slow)).all()
    assert p.cost.bytes == 2 * 8 * 128 * np.dtype(dtype).itemsize


@pytest.mark.parametrize("dtype", DTYPES)
def test_policy_tier_round_trip_bit_exact(dtype):
    """compute -> slow -> compute through the policy-mediated tier legs
    (pack, tier_write, tier_read, unpack): bit-exact per dtype."""
    cache = {"a": _rand(jax.random.key(3), (2, 3, 5, 7), dtype),
             "b": _rand(jax.random.key(4), (1, 3, 11), jnp.int32)}
    spec = MV.PageSpec.for_cache(cache)
    cfg = VillaConfig(n_counters=4, n_hot=2, n_slots=2, epoch_len=4)
    store = VC.make_store(jnp.zeros((4, spec.n_pages, 8, 128), jnp.uint8),
                          cfg)
    lay = MV.Layout.pages(spec)
    susp = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"), lay,
                               policy=cfg))
    resu = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("compute"), lay,
                               policy=cfg))
    store = MV.execute(susp, cache=cache, slot=jnp.int32(1), store=store,
                       item=jnp.int32(2))["store"]
    blank = jax.tree.map(jnp.zeros_like, cache)
    out = MV.execute(resu, cache=blank, slot=jnp.int32(1), store=store,
                     item=jnp.int32(2))["cache"]
    for name in cache:
        got, want = out[name][:, 1], cache[name][:, 1]
        assert got.dtype == want.dtype
        assert (np.asarray(got) == np.asarray(want)).all(), name


@pytest.mark.parametrize("dtype", DTYPES)
def test_host_stage_backend_round_trip_bit_exact(dtype):
    leaves = [_rand(jax.random.key(5), (6, 9), dtype),
              _rand(jax.random.key(6), (4,), jnp.int32), None]
    down = MV.plan(MV.Transfer(MV.Tier("device"), MV.Tier("host"),
                               MV.Layout.tree([l for l in leaves
                                               if l is not None])))
    up = MV.plan(MV.Transfer(MV.Tier("host"), MV.Tier("device"),
                             MV.Layout.tree([l for l in leaves
                                             if l is not None])))
    hosted = MV.execute(down, data=leaves)["data"]
    assert hosted[2] is None and isinstance(hosted[0], np.ndarray)
    back = MV.execute(up, data=hosted)["data"]
    for a, b in zip(back[:2], leaves[:2]):
        assert a.dtype == b.dtype
        assert (np.asarray(a) == np.asarray(b)).all()
    # host legs price both mechanisms on the channel (no in-fabric path)
    assert down.cost.ns_lisa == down.cost.ns_memcpy > 0


def test_batched_tier_read_is_one_fused_wave():
    """A fused resume wave (batch k) reads k items in one scanned dispatch
    and matches k sequential single reads item-for-item."""
    cfg = VillaConfig(n_counters=8, n_hot=2, n_slots=2, epoch_len=4)
    pool = _rand(jax.random.key(7), (8, 4, 8, 128), jnp.uint8)
    lay = MV.Layout.raw_pages(4, 8, 128, jnp.uint8)
    single = MV.plan(MV.Transfer(MV.Tier("slow"), MV.Tier("compute"), lay,
                                 policy=cfg))
    assert [l.kind for l in single.legs] == ["tier_read"]  # raw: no unpack
    wave = MV.fuse([single] * 3)
    ids = jnp.asarray([5, 1, 5], jnp.int32)

    st_b = VC.make_store(pool, cfg)
    env = MV.execute(wave, store=st_b, items=ids)
    st_s = VC.make_store(pool, cfg)
    seq = []
    for i in ids:
        st_s, data, _ = VC.access(st_s, i, cfg)
        seq.append(data)
    assert (np.asarray(env["data"]) == np.asarray(jnp.stack(seq))).all()
    assert np.array_equal(np.asarray(env["store"].policy.counters),
                          np.asarray(st_s.policy.counters))


HOP_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import movement as MV
from repro.core.lisa.topology import MeshTopology

mesh = jax.make_mesh((4,), ("x",))
x = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)

def run(plan):
    return np.asarray(jax.jit(jax.shard_map(
        lambda s: MV.execute(plan, data=s)["data"],
        mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x))

p_copy = MV.plan(MV.Transfer(MV.Tier("stage", index=0, axis="x"),
                             MV.Tier("stage", index=2, axis="x"),
                             MV.Layout.dense((8,), jnp.float32)),
                 topo=MeshTopology(4))
assert p_copy.legs[0].hops == 2
want = np.asarray(x).copy()
want[2] = np.asarray(x)[0]              # dst holds src's shard
assert (run(p_copy) == want).all()

# ring topology: 3 -> 0 prices ONE hop and executes over the wrap link
p_wrap = MV.plan(MV.Transfer(MV.Tier("stage", index=3, axis="x"),
                             MV.Tier("stage", index=0, axis="x"),
                             MV.Layout.dense((8,), jnp.float32)),
                 topo=MeshTopology(4))
assert p_wrap.legs[0].hops == 1 and p_wrap.legs[0].wraparound
want = np.asarray(x).copy()
want[0] = np.asarray(x)[3]
assert (run(p_wrap) == want).all()

# linear topology (no wrap links): 3 -> 0 prices THREE hops and the chain
# walks backward — priced route == executed route
p_lin = MV.plan(MV.Transfer(MV.Tier("stage", index=3, axis="x"),
                            MV.Tier("stage", index=0, axis="x"),
                            MV.Layout.dense((8,), jnp.float32)),
                topo=MeshTopology(4, wraparound=False))
assert p_lin.legs[0].hops == 3 and not p_lin.legs[0].wraparound
assert (run(p_lin) == want).all()
assert p_lin.cost.ns_lisa == 3 * p_wrap.cost.ns_lisa

p_shift = MV.plan(MV.Transfer(MV.Tier("stage", axis="x"),
                              MV.Tier("stage", axis="x"),
                              MV.Layout.dense((8,), jnp.float32)))
assert (run(p_shift) == np.roll(np.asarray(x), 1, axis=0)).all()
print("HOP_OK")
"""


def test_hop_chain_backend_moves_shards_on_mesh():
    out = run_with_devices(HOP_CODE, 4)
    assert "HOP_OK" in out
