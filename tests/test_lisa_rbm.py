"""Ring/hop primitives vs oracles on an 8-device mesh (subprocess)."""
from _multidev import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.lisa import rbm, compression as C

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

def smap(f, in_specs=P("x"), out_specs=P("x")):
    return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs))

# point-to-point, both directions incl. wraparound
for src, dst in [(2, 6), (6, 2), (0, 7), (7, 1)]:
    cp = smap(lambda s, src=src, dst=dst: rbm.lisa_copy(s, src, dst, "x"))(x)
    assert (cp == x.at[dst].set(x[src])).all(), (src, dst)

# 1-to-N multicast with intermediate latching
bc = smap(lambda s: rbm.lisa_broadcast(s, 3, "x", dsts=[0, 5, 7]))(x)
exp = x.at[0].set(x[3]).at[5].set(x[3]).at[7].set(x[3])
assert (bc == exp).all()
bca = smap(lambda s: rbm.lisa_broadcast(s, 3, "x"))(x)
assert (bca == jnp.broadcast_to(x[3], x.shape)).all()

# ring collectives vs dense oracles
ag = smap(lambda s: rbm.ring_allgather(s, "x"), out_specs=P("x", None))(x)
assert (ag.reshape(8, 8, 4)[0] == x).all()
ar = smap(lambda s: rbm.ring_allreduce(s, "x"))(x)
assert jnp.allclose(ar, jnp.broadcast_to(x.sum(0), (8, 4)))
rs_in = jax.random.normal(jax.random.key(1), (8, 8, 4))
rs = smap(lambda s: rbm.ring_reduce_scatter(s[0], "x")[None])(rs_in)
assert jnp.allclose(rs, rs_in.sum(0), atol=1e-5)

# overlapped allgather-matmul == dense matmul
w = jax.random.normal(jax.random.key(2), (8, 2, 3))
xx = jax.random.normal(jax.random.key(3), (8, 5, 16))
mm = jax.jit(jax.shard_map(
    lambda xs, ws: rbm.ring_allgather_matmul(xs[0], ws[0], "x")[None],
    mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x")))(xx, w)
assert jnp.allclose(mm[0], xx[0] @ w.reshape(16, 3), atol=1e-4)

# int8 error-feedback allreduce ~= exact mean
gr = jax.random.normal(jax.random.key(4), (8, 100))
got = jax.jit(jax.shard_map(
    lambda gg: C.allreduce_mean_compressed(gg[0], jnp.zeros(100), "x")[0][None],
    mesh=mesh, in_specs=P("x"), out_specs=P("x")))(gr)
assert jnp.allclose(got[0], gr.mean(0), atol=2e-2)
print("RBM_OK")
"""


def test_rbm_primitives_8dev():
    out = run_with_devices(CODE, 8)
    assert "RBM_OK" in out
