"""Checkpointing: roundtrip, atomicity, GC, resume determinism, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _multidev import run_with_devices
from repro.checkpoint import manager as ckpt
from repro.configs import get_reduced
from repro.train.step import ParallelConfig, init_train_state


def _state():
    return init_train_state(get_reduced("tinyllama-1.1b"),
                            jax.random.key(0), ParallelConfig(fsdp=False))


def test_roundtrip(tmp_path):
    state = _state()
    ckpt.save(state, str(tmp_path), 7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore(state, str(tmp_path))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_roundtrip_with_scalar_leaves(tmp_path):
    """Trees may carry plain Python / numpy scalar leaves (step counters,
    hyperparameters): staging must size and move them, not crash
    (regression for the movement-planned host staging)."""
    tree = {"w": jnp.ones((2, 3)), "step": 7, "lr": np.float64(0.1)}
    ckpt.save(tree, str(tmp_path), 1)
    cost = ckpt.last_move_cost()
    assert cost is not None and cost.bytes >= 6 * 4 + 8 + 8
    back = ckpt.restore(tree, str(tmp_path))
    assert int(back["step"]) == 7
    assert float(back["lr"]) == pytest.approx(0.1)
    assert np.allclose(np.asarray(back["w"]), 1.0)


def test_gc_keeps_last_k(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(state, str(tmp_path), s, keep_last=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_no_tmp_left_behind(tmp_path):
    state = _state()
    ckpt.save(state, str(tmp_path), 1)
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_restore_specific_step(tmp_path):
    state = _state()
    s1 = state._replace(step=jnp.int32(1))
    ckpt.save(s1, str(tmp_path), 1)
    s2 = state._replace(step=jnp.int32(2))
    ckpt.save(s2, str(tmp_path), 2)
    back = ckpt.restore(state, str(tmp_path), step=1)
    assert int(back.step) == 1


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(_state(), str(tmp_path / "nope"))


def test_restore_rejects_torn_or_truncated_checkpoint(tmp_path):
    """Crash-consistency regression: the crc trailer (written LAST inside
    the tmp dir, before the atomic rename) catches every partial-write
    shape — truncation, in-place corruption, missing trailer — as a typed
    CorruptCheckpoint instead of restoring garbage."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}

    ckpt.save(tree, str(tmp_path), 1)           # truncated payload
    npz = tmp_path / "step_00000001" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])
    with pytest.raises(ckpt.CorruptCheckpoint, match="truncated"):
        ckpt.restore(tree, str(tmp_path), step=1)

    ckpt.save(tree, str(tmp_path), 2)           # same-size bit rot
    npz = tmp_path / "step_00000002" / "arrays.npz"
    raw = bytearray(npz.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    npz.write_bytes(bytes(raw))
    with pytest.raises(ckpt.CorruptCheckpoint, match="crc"):
        ckpt.restore(tree, str(tmp_path), step=2)

    ckpt.save(tree, str(tmp_path), 3)           # trailer never landed
    (tmp_path / "step_00000003" / "trailer.json").unlink()
    with pytest.raises(ckpt.CorruptCheckpoint, match="trailer"):
        ckpt.restore(tree, str(tmp_path), step=3)

    ckpt.save(tree, str(tmp_path), 4)           # intact step still restores
    back = ckpt.restore(tree, str(tmp_path), step=4)
    assert np.allclose(np.asarray(back["w"]), np.arange(64.0).reshape(8, 8))
    assert ckpt.verify_checkpoint(str(tmp_path), 4) is None


ELASTIC = r"""
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import manager as ckpt

tmp = sys.argv[1]
tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}
ckpt.save(tree, tmp, 1)

# restore onto a 2x4 mesh (elastic rescale: different layout than writer)
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model")),
      "b": NamedSharding(mesh, P("model"))}
back = ckpt.restore(tree, tmp, shardings=sh)
assert np.allclose(np.asarray(back["w"]), np.arange(64.0).reshape(8, 8))
assert back["w"].sharding.spec == P("data", "model")
print("ELASTIC_OK")
"""


def test_elastic_restore_different_mesh(tmp_path):
    import sys
    code = ELASTIC.replace("sys.argv[1]", repr(str(tmp_path)))
    out = run_with_devices(code, 8)
    assert "ELASTIC_OK" in out
