"""Paged KV snapshots: dtype preservation, bit-exact round-trips, tiering."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.models import lm
from repro.serve import paged_store as PS

CFG = VillaConfig(n_counters=8, n_hot=2, n_slots=2, epoch_len=4)


def _mixed_cache(slots=3):
    """A cache-shaped pytree with float32 / int32 / int8 / bfloat16 leaves —
    the dtype mix of fp, quantised-KV and position buffers."""
    k = jax.random.key(0)
    return {
        "k": jax.random.normal(k, (2, slots, 7, 3), jnp.float32),
        "pos": jax.random.randint(k, (2, slots, 7), 0, 2**30)
        .astype(jnp.int32),
        "kq": jax.random.randint(k, (1, slots, 5, 2), -127, 127)
        .astype(jnp.int8),
        "scale": jax.random.normal(k, (1, slots, 5), jnp.float32)
        .astype(jnp.bfloat16),
    }


def test_pack_unpack_roundtrip_bit_exact_all_dtypes():
    cache = _mixed_cache()
    spec = PS.PageSpec.for_cache(cache)
    pages = PS.pack_slot(spec, cache, jnp.int32(1))
    assert pages.dtype == jnp.uint8
    assert pages.shape == (spec.n_pages, 8, 128)
    # true byte total: no float32 upcast anywhere
    exact = sum(np.prod(l.shape[:1] + l.shape[2:]) * l.dtype.itemsize
                for l in jax.tree.leaves(cache))
    assert spec.total_bytes == exact

    blank = jax.tree.map(jnp.zeros_like, cache)
    out = PS.unpack_into_slot(spec, blank, jnp.int32(1), pages)
    for name in cache:
        got, want = out[name][:, 1], cache[name][:, 1]
        assert got.dtype == want.dtype, name
        assert (got == want).all(), name
        # other slots untouched
        assert (out[name][:, 0] == 0).all() and (out[name][:, 2] == 0).all()


def test_pack_is_jit_traceable_over_slots():
    cache = _mixed_cache()
    spec = PS.PageSpec.for_cache(cache)
    packer = jax.jit(lambda c, s: PS.pack_slot(spec, c, s))
    p0 = packer(cache, jnp.int32(0))
    p2 = packer(cache, jnp.int32(2))
    assert packer._cache_size() == 1          # traced slot: one compilation
    assert not (np.asarray(p0) == np.asarray(p2)).all()


def test_session_store_suspend_resume_via_tiers():
    cache = _mixed_cache()
    spec = PS.PageSpec.for_cache(cache)
    store = PS.make_session_store(spec, n_sessions=6, cfg=CFG)
    pages1 = PS.pack_slot(spec, cache, jnp.int32(1))
    store = VC.write(store, jnp.int32(4), pages1)
    for _ in range(10):                        # make session 4 hot + resident
        store, got, hit = VC.access(store, jnp.int32(4), CFG)
        assert (got == pages1).all()
    assert bool(hit)                           # resumed from the fast tier
    out = PS.unpack_into_slot(spec, jax.tree.map(jnp.zeros_like, cache),
                              jnp.int32(1), got)
    for name in cache:
        assert (out[name][:, 1] == cache[name][:, 1]).all(), name


def test_real_model_cache_layout():
    cfg = get_reduced("tinyllama-1.1b")
    cache = lm.init_cache(cfg, 2, max_len=32)
    spec = PS.PageSpec.for_cache(cache)
    pages = PS.pack_slot(spec, cache, jnp.int32(0))
    out = PS.unpack_into_slot(spec, jax.tree.map(jnp.zeros_like, cache),
                              jnp.int32(0), pages)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(cache)):
        assert a.dtype == b.dtype
        assert (a[:, 0] == b[:, 0]).all()
