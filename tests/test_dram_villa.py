"""VILLA policy invariants (paper Sec. 3.2.1), property-based."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.dram.villa import (COUNTER_SATURATION, VillaConfig,
                                   villa_access, villa_epoch, villa_init)

CFG = VillaConfig(n_counters=32, n_hot=4, n_slots=4, epoch_len=16)


def _run(ids, cfg=CFG):
    state = villa_init(cfg)
    outs = []
    for i in ids:
        state, hit, insert, victim = villa_access(state, jnp.int32(i), cfg)
        outs.append((bool(hit), bool(insert), int(victim)))
    return state, outs


def test_insert_only_when_hot():
    state = villa_init(CFG)
    # before any epoch, nothing is hot: no inserts ever
    for i in range(10):
        state, hit, insert, _ = villa_access(state, jnp.int32(i), CFG)
        assert not bool(insert)
        assert not bool(hit)


def test_hot_rows_get_cached_then_hit():
    ids = [1, 2, 1, 2, 1, 2, 1, 2] * 4        # 32 accesses -> 2 epochs
    state, outs = _run(ids)
    assert any(i for _, i, _ in outs), "hot rows were never inserted"
    assert any(h for h, _, _ in outs), "cached rows never hit"
    assert 1 in np.asarray(state.tags) and 2 in np.asarray(state.tags)


def test_epoch_halves_counters():
    state = villa_init(CFG)
    for _ in range(5):
        state, *_ = villa_access(state, jnp.int32(3), CFG)
    before = int(state.counters[3])
    state2 = villa_epoch(state, CFG)
    assert int(state2.counters[3]) == before // 2
    assert int(state2.tick) == 0


def test_top_k_marked_hot():
    state = villa_init(CFG)
    for i, n in [(1, 5), (2, 4), (3, 3), (4, 2), (5, 1)]:
        for _ in range(n):
            state, *_ = villa_access(state, jnp.int32(i), CFG)
    state = villa_epoch(state, CFG)
    hot = np.asarray(state.hot)
    assert hot[[1, 2, 3, 4]].all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=1, max_size=120))
def test_villa_invariants(ids):
    state, outs = _run(ids)
    c = np.asarray(state.counters)
    assert (c >= 0).all() and (c <= COUNTER_SATURATION).all()
    tags = np.asarray(state.tags)
    live = tags[tags >= 0]
    assert len(np.unique(live)) == len(live), "duplicate rows in fast tier"
    ben = np.asarray(state.benefit)
    assert (ben >= 0).all()
    # a hit must mean the row was resident: re-simulate forward
    resident = set()
    for i, (hit, insert, _) in zip(ids, outs):
        if hit:
            assert i in resident
        if insert:
            resident.add(i)
    # no more residents than slots (evictions shrink the *set* we model
    # optimistically, so only check the real end state)
    assert (tags >= -1).all() and len(tags) == CFG.n_slots


def test_saturation():
    cfg = VillaConfig(n_counters=4, n_hot=1, n_slots=1, epoch_len=10**9)
    state = villa_init(cfg)

    @jax.jit
    def run(state):
        def body(s, _):
            s, *_ = villa_access(s, jnp.int32(0), cfg)
            return s, 0
        return jax.lax.scan(body, state,
                            None, length=COUNTER_SATURATION + 50)[0]

    state = run(state)
    assert int(state.counters[0]) == COUNTER_SATURATION
