"""Serving with LISA-VILLA session tiering (deliverable b).

A continuous-batching engine serves a stream of requests on the
device-resident hot path: every decode step is ONE jitted dispatch and ONE
device→host transfer however ragged the slot positions are, and finished
sessions are suspended into a paged, dtype-preserving tiered store through
the Pallas RBM kernels.  A skewed resume pattern (chat-style hot sessions)
drives the paper's caching policy: watch the fast-tier hit rate climb —
promotions are the bulk KV moves LISA-RISC accelerates on hardware.  Resume
waves drain in one batched dispatch (``resume_many``).

Run:  PYTHONPATH=src python examples/serve_villa.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Engine, Request

cfg = get_reduced("tinyllama-1.1b")
params = lm.init_lm(cfg, jax.random.key(0))
eng = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
rng = np.random.default_rng(0)

print("phase 1: serving 12 fresh requests (continuous batching, ragged "
      "prompt lengths)...")
pending = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8 + i % 5)
                   .astype(np.int32), max_new=6) for i in range(12)]
while pending or eng.active:
    while pending and eng.free_slots():
        eng.submit(pending.pop(0))
    eng.step()
print(f"  decoded {eng.stats['decoded_tokens']} tokens in "
      f"{eng.stats['decode_dispatches']} dispatches / "
      f"{eng.stats['host_transfers']} host transfers "
      f"({eng.compile_counts()['decode']} decode compilation), "
      f"{eng.stats['suspends']} sessions suspended")

print("phase 2: 40 resumes in waves of 4, 85% to 3 hot sessions...")
for _ in range(10):
    wave = []
    while len(wave) < 4:
        uid = int(rng.integers(0, 3)) if rng.random() < 0.85 else \
            int(rng.integers(0, 12))
        if uid not in wave:
            wave.append(uid)
    eng.resume_many(wave, extra_new=3)          # one dispatch for the wave
    while eng.active:
        eng.step()
print(f"  VILLA fast-tier hit rate: {eng.hit_rate():.2f} "
      f"(cold-start misses included)")
print(f"  KV snapshots: {eng.snapshot_bytes} true bytes "
      f"({eng.page_spec.n_pages} x 1KB pages, dtypes preserved)")
print(f"  totals: {eng.stats}")
