"""Serving with LISA-VILLA session tiering under the cost-aware scheduler.

A continuous-batching engine serves a bursty, Zipf-skewed traffic stream —
but every placement decision is made by the ``repro.sched`` scheduler, the
controller layer the paper argues for: admissions queue (never crash the
engine), suspend/resume drain as fused waves (ONE dispatch per wave), the
next wave is planned *while* the decode dispatch is in flight (the LISA-LIP
linked-precharge analogue), and the ``cost_aware`` policy scores every
suspend/resume candidate by its plan's modeled Table-1 cost and VILLA
fast-tier occupancy.  Watch the fast-tier hit rate climb as hot sessions
keep returning — promotions are the bulk KV moves LISA-RISC accelerates on
hardware, and the movement summary prices the same schedule under ``lisa``
vs ``memcpy``.

Run:  PYTHONPATH=src python examples/serve_villa.py
"""
import jax

from repro import sched
from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Engine

cfg = get_reduced("tinyllama-1.1b")
params = lm.init_lm(cfg, jax.random.key(0))

wl = sched.WorkloadConfig(
    n_fresh=12, n_followups=40, mean_gap_ns=1_200.0,
    arrival="bursty", burst=4,            # chat bursts hit the queue at once
    zipf_s=1.4, think_ns=3_000.0,         # 3 hot sessions dominate re-use
    class_slo_ns=(120_000.0, 400_000.0, float("inf")))
arrivals = sched.generate_workload(wl, seed=0, vocab_size=cfg.vocab_size)
print(f"traffic: {wl.n_fresh} fresh sessions + {wl.n_followups} follow-ups, "
      f"bursts of {wl.burst}, Zipf(s={wl.zipf_s}) session re-use")

eng = Engine(cfg, params, slots=4, max_len=96,
             n_sessions=sched.n_sessions_for(wl))
s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
summary = s.run()

print(f"served {summary['jobs_completed']} jobs / {summary['tokens']} tokens "
      f"in {s.tick_count} ticks "
      f"({eng.stats['decode_dispatches']} decode dispatches, "
      f"{eng.compile_counts()['decode']} decode compilation)")
print(f"  per class: " + ", ".join(
    f"class {c}: p99 {v['p99_latency_ns']/1e3:.1f}us "
    f"(SLO {v['slo_attainment']:.0%})"
    for c, v in summary["per_class"].items()))
print(f"  slot utilization {summary['slot_utilization']:.0%}, decisions "
      f"{summary['decisions']}")
resume_waves = s.metrics.wave_widths("resume_wave")
print(f"  {eng.stats['resumes']} resumes drained in {len(resume_waves)} "
      f"fused waves {resume_waves} — one dispatch per wave")
print(f"  VILLA fast-tier hit rate: {eng.hit_rate():.2f} "
      f"(cold-start misses included)")
print(f"  movement bill: lisa {summary['movement']['ns_lisa']/1e3:.1f}us "
      f"vs memcpy {summary['movement']['ns_memcpy']/1e3:.1f}us "
      f"({summary['movement']['advantage']:.1f}x — Table 1 at serving scale)")
print(f"  KV snapshots: {eng.snapshot_bytes} true bytes "
      f"({eng.page_spec.n_pages} x 1KB pages, dtypes preserved)")
