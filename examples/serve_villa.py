"""Serving with LISA-VILLA session tiering (deliverable b).

A continuous-batching engine serves a stream of requests; finished sessions
are suspended into the tiered store. A skewed resume pattern (chat-style hot
sessions) drives the paper's caching policy: watch the fast-tier hit rate
climb — promotions are the bulk KV moves LISA-RISC accelerates on hardware.

Run:  PYTHONPATH=src python examples/serve_villa.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Engine, Request

cfg = get_reduced("tinyllama-1.1b")
params = lm.init_lm(cfg, jax.random.key(0))
eng = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
rng = np.random.default_rng(0)

print("phase 1: serving 12 fresh requests (continuous batching)...")
pending = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 12)
                   .astype(np.int32), max_new=6) for i in range(12)]
while pending or eng.active:
    while pending and eng.free_slots():
        eng.submit(pending.pop(0))
    eng.step()
print(f"  decoded {eng.stats['decoded_tokens']} tokens, "
      f"{eng.stats['suspends']} sessions suspended")

print("phase 2: 40 resumes, 85% to 3 hot sessions...")
for i in range(40):
    uid = int(rng.integers(0, 3)) if rng.random() < 0.85 else \
        int(rng.integers(0, 12))
    eng.resume(uid, extra_new=3)
    while eng.active:
        eng.step()
print(f"  VILLA fast-tier hit rate: {eng.hit_rate():.2f} "
      f"(cold-start misses included)")
print(f"  totals: {eng.stats}")
