"""Elastic rescaling (fault tolerance): checkpoint on one mesh, resume on a
different one.  The relayout is the bulk cross-device movement that the LISA
substrate accelerates (checkpoint restore -> NamedSharding placement; on a
live cluster the same plan runs as lisa_copy hop chains).

Run:  PYTHONPATH=src python examples/elastic_rescale.py
(Spawns subprocesses with 8 forced host devices.)
"""
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PHASE1 = """
import jax
from repro.checkpoint import manager as ckpt
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh
from repro.train.step import ParallelConfig, init_train_state, make_train_step

cfg = get_reduced("tinyllama-1.1b")
mesh = make_local_mesh(4, 2)                       # 8 chips: 4-way DP x 2 TP
pcfg = ParallelConfig(fsdp=True)
state = init_train_state(cfg, jax.random.key(0), pcfg)
_, compile_step, _ = make_train_step(cfg, mesh, pcfg)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
b = batch_at(dcfg, 0)
step = compile_step(*jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (state, b)))
for i in range(3):
    state, m = step(state, batch_at(dcfg, i))
ckpt.save(state, DIR, 3)
print("phase1 loss:", float(m["loss"]))
"""

PHASE2 = """
import jax
from repro.checkpoint import manager as ckpt
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh
from repro.train.step import ParallelConfig, init_train_state, make_train_step

cfg = get_reduced("tinyllama-1.1b")
mesh = make_local_mesh(2, 2)                       # "lost" 4 chips: 2x2 mesh
pcfg = ParallelConfig(fsdp=True)
template = init_train_state(cfg, jax.random.key(0), pcfg)
_, compile_step, state_shardings = make_train_step(cfg, mesh, pcfg)
sh = state_shardings(jax.tree.map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), template))
state = ckpt.restore(template, DIR, shardings=sh)   # elastic relayout
print("resumed at step", int(state.step), "on", mesh.devices.shape)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
b = batch_at(dcfg, 3)
step = compile_step(*jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (state, b)))
state, m = step(state, b)
print("phase2 (rescaled) loss:", float(m["loss"]))
"""

if __name__ == "__main__":
    d = tempfile.mkdtemp()
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    for phase in (PHASE1, PHASE2):
        r = subprocess.run([sys.executable, "-c",
                            f"DIR={d!r}\n" + phase], env=env)
        assert r.returncode == 0
    print("elastic rescale OK: 4x2 -> 2x2 resume succeeded")
