"""End-to-end training driver (deliverable b): trains a ~100M-parameter
llama-family model for a few hundred steps on the synthetic copy-task
corpus, with periodic checkpoints and crash-safe resume.

The full 100M config takes ~1-2 s/step on a single CPU core; pass --small
for a CI-sized run (the assertions are the same).

Run:  PYTHONPATH=src python examples/train_e2e.py [--small]
"""
import argparse
import dataclasses
import sys

import jax

from repro.configs.base import ModelConfig
from repro.launch.train import main as train_main

# ~100M-parameter llama-style config (decoder-only, GQA, SwiGLU)
M100 = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, activation="swiglu", remat=False,
    attn_block=256, scan_chunk=64)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    import repro.configs as C
    cfg = M100
    if args.small:
        cfg = dataclasses.replace(M100, n_layers=4, d_model=256, n_heads=4,
                                  n_kv_heads=2, d_ff=688, vocab_size=2048)
    steps = args.steps or (60 if args.small else 300)
    # register so --arch finds it
    C._MODULES["llama-100m"] = type("M", (), {"CONFIG": cfg, "REDUCED": cfg})
    res = train_main(["--arch", "llama-100m", "--steps", str(steps),
                      "--batch", "4", "--seq", "256", "--lr", "1e-3",
                      "--ckpt-dir", "/tmp/lisa_e2e_ckpt", "--ckpt-every",
                      str(max(steps // 5, 1)), "--log-every", "10"])
    assert res["last_loss"] < res["first_loss"], "training did not learn"
    print(f"OK: loss {res['first_loss']:.3f} -> {res['last_loss']:.3f} "
          f"over {res['steps']} steps ({res['seconds']}s)")
