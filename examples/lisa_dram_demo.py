"""Full reproduction demo: Table 1 + Figs. 3/4 orderings on synthetic
multiprogrammed workloads (the paper's system evaluation, Sec. 3), under the
`DramSpec` device-model API.

Run:  PYTHONPATH=src python examples/lisa_dram_demo.py
"""
import jax

from repro.core.dram.controller import (MechanismConfig, simulate,
                                        weighted_speedup)
from repro.core.dram.spec import DDR3_1600, DDR4_2400
from repro.core.dram.traces import TraceConfig, generate

spec = DDR3_1600
print(f"=== Table 1 (8 KB copy, preset {spec.name}) ===")
print(f"{'mechanism':14s} {'latency ns':>10s} {'energy uJ':>10s}")
for mech, (lat, ene) in spec.table1().items():
    print(f"{mech:14s} {lat:10.2f} {ene:10.4f}")
print(f"\nRBM bandwidth: {spec.rbm_bw_gbps:.0f} GB/s = "
      f"{spec.rbm_bw_gbps/spec.channel_bw_gbps:.1f}x a DDR4-2400 channel "
      f"(paper: 26x)")
print(f"LIP precharge: {spec.precharge_latency(False):.0f} ns -> "
      f"{spec.precharge_latency(True):.0f} ns (paper: 2.6x)")

print("\n=== System evaluation (4-core synthetic workloads) ===")
tcfg = TraceConfig(n_requests=16384)
tr = generate(jax.random.key(1), tcfg, spec)
base = simulate(tr, tcfg, MechanismConfig("memcpy"), spec)
for name, mcfg, paper in [
    ("RowClone-InterSA", MechanismConfig("rc_intersa"), ""),
    ("LISA-RISC", MechanismConfig("lisa"), "paper: +59.6%"),
    ("LISA-(RISC+VILLA)", MechanismConfig("lisa", use_villa=True),
     "paper: +16.5% over RISC"),
    ("LISA-ALL", MechanismConfig("lisa", use_villa=True, use_lip=True),
     "paper: +94.8% total, +8.8% from LIP"),
    ("RC-InterSA+VILLA", MechanismConfig("memcpy", use_villa=True,
                                         villa_copy_mech="rc_intersa"),
     "paper: -52.3% (slow copies kill caching)"),
]:
    r = simulate(tr, tcfg, mcfg, spec)
    ws = float(weighted_speedup(base["core_stall"], r["core_stall"]))
    ene = 1 - float(r["energy_uJ"]) / float(base["energy_uJ"])
    hit = float(r["villa_hit_rate"])
    print(f"{name:18s} WS {ws:6.3f}x  energy {ene:+.1%}  hit {hit:.2f}  {paper}")

# Every simulate() above — all mechanisms, VILLA, LIP — reused ONE jitted
# compilation (mechanism config is traced data).  Other presets are one
# argument away:
print(f"\n=== Preset sweep: LISA-RISC-7 latency across devices ===")
for s in (DDR3_1600, DDR4_2400):
    print(f"{s.name:12s} {s.copy_latency('lisa', 7):8.2f} ns "
          f"(RC-InterSA {s.copy_latency('rc_intersa'):8.2f} ns)")
