"""Quickstart: the LISA substrate in five minutes.

1. The faithful DRAM reproduction: an 8 KB row copy via RBM hop chains,
   with Table-1-exact latency/energy.
2. The TPU adaptation: the same policy object driving a tiered KV store.
3. A few training steps of a reduced assigned architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# --- 1. DRAM substrate: LISA-RISC copy ------------------------------------
from repro.core.dram import substrate as S
from repro.core.dram.spec import DDR3_1600

# full 8 KB rows so every modeled cost is Table-1 exact (2 MB of cells)
spec = DDR3_1600.with_geometry(16, 16)
bank = S.make_bank(spec, key=jax.random.key(0))
bank2, lat, ene = S.lisa_risc_copy(bank, src_sa=1, src_row=3,
                                   dst_sa=8, dst_row=5, spec=spec)
assert (bank2.cells[8, 5] == bank.cells[1, 3]).all()
print(f"LISA-RISC copy  (7 hops): {lat:.2f} ns, {ene:.4f} uJ "
      f"(paper Table 1: 196.5 ns / 0.12 uJ)")
print(f"RowClone InterSA baseline: {spec.copy_latency('rc_intersa'):.2f} ns "
      f"/ {spec.copy_energy('rc_intersa'):.2f} uJ -> "
      f"{spec.copy_latency('rc_intersa')/lat:.1f}x slower")

# --- 2. 1-to-N multicast (paper Sec. 5.2) ----------------------------------
bank3, lat_b, _ = S.lisa_broadcast(bank, 1, 3, dst_sas=(4, 9, 14), dst_row=2,
                                   spec=spec)
assert all((bank3.cells[d, 2] == bank.cells[1, 3]).all() for d in (4, 9, 14))
print(f"1-to-3 multicast via intermediate latching: {lat_b:.2f} ns "
      f"(vs 3 separate copies: {3*lat:.2f} ns)")

# --- 3. VILLA tiered store (TPU-side, same policy) --------------------------
from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC

cfg = VillaConfig(n_counters=32, n_hot=4, n_slots=4, epoch_len=8)
store = VC.make_store(jax.random.normal(jax.random.key(1), (32, 8)), cfg)
for i in [3, 9] * 16:                       # two hot items
    store, data, hit = VC.access(store, jnp.int32(i), cfg)
print(f"VILLA tiered store hit rate after warmup: {float(VC.hit_rate(store)):.2f}")

# --- 4. Train a reduced assigned architecture a few steps ------------------
from repro.launch.train import main as train_main

res = train_main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "10",
                  "--batch", "4", "--seq", "64", "--log-every", "5"])
print(f"loss {res['first_loss']:.3f} -> {res['last_loss']:.3f} in 10 steps")
